"""Faithful reproduction of the paper's experiments (§3, App. A/B).

MNIST is unavailable offline; the synthetic teacher-student task
(784 -> 10, DESIGN.md §6) stands in.  Absolute accuracies therefore
differ from the paper's MNIST numbers; the claims validated are the
paper's *relative* statements — see EXPERIMENTS.md for the mapping.

Every function returns a list of row-dicts (benchmark CSV / markdown).
``quick=True`` shrinks grids/steps for the CI-scale benchmark run; the
full grids match the paper (5 seeds, d in {1,5,10,50,100}, m/n = 2^i).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..comm.metering import round_wire_report, wire_table
from ..core import (
    FederatedConfig,
    ZamplingConfig,
    build_specs,
    federated_round,
    init_state,
    sample_weights,
)
from ..core.zonotope import perturb_nontrivial, tau_hypercube_dim
from ..data import iid_client_split, make_teacher_dataset, client_batch_stream
from ..models.mlp import (
    MNISTFC_DIMS,
    SMALL_DIMS,
    init_mlp_params,
    mlp_accuracy,
    mlp_loss,
    param_count,
)
from ..optim import adam
from ..optim.optimizers import apply_updates
from ..train import LocalTrainConfig, evaluate, train_local_zampling

_DS = {}


def _dataset(seed=0):
    if seed not in _DS:
        _DS[seed] = make_teacher_dataset(n_train=8000, n_test=1500, seed=seed)
    return _DS[seed]


def _setup(dims, compression, d, seed, beta: Optional[tuple] = None):
    template = init_mlp_params(jax.random.PRNGKey(seed), dims)
    zspecs = build_specs(
        template,
        ZamplingConfig(compression=compression, d=d, window=128, seed=seed,
                       min_size=128),
    )
    state = init_state(jax.random.PRNGKey(seed + 1), zspecs,
                       dense_init=template)
    if beta is not None:
        from ..core.sampling import init_scores

        state["scores"] = {
            p: init_scores(jax.random.fold_in(jax.random.PRNGKey(seed + 2),
                                              i), s.shape[0],
                           dist="beta", beta_a=beta[0], beta_b=beta[1])
            for i, (p, s) in enumerate(state["scores"].items())
        }
    return zspecs, state


def _train(zspecs, state, ds, steps, lr, mode="sample", seed=0):
    batches = (
        {"x": jnp.asarray(x), "y": jnp.asarray(y)}
        for x, y in ds.batches(128, seed=seed)
    )
    cfg = LocalTrainConfig(steps=steps, lr=lr, mode=mode,
                           eval_every=10**9, seed=seed)
    state, hist = train_local_zampling(zspecs, state, mlp_loss, batches, cfg)
    return state, hist


def _acc_fn(ds):
    tb = {"x": jnp.asarray(ds.x_test), "y": jnp.asarray(ds.y_test)}
    return jax.jit(lambda p: mlp_accuracy(p, tb))


# ---------------------------------------------------------------------------
# §3.1 / Table 2 / Fig 3 — compression-accuracy tradeoff across d
# ---------------------------------------------------------------------------

def run_local_compression(quick: bool = True) -> List[Dict]:
    ds = _dataset()
    acc = _acc_fn(ds)
    ds_list = [1, 5, 10] if quick else [1, 5, 10, 50, 100]
    comps = [1, 4, 32] if quick else [2**i for i in range(11)]
    seeds = [0] if quick else [0, 1, 2, 3, 4]
    steps = 800 if quick else 4000
    rows = []
    for d in ds_list:
        for c in comps:
            accs_sampled, accs_expected = [], []
            for seed in seeds:
                t0 = time.time()
                zspecs, state = _setup(SMALL_DIMS, c, d, seed)
                state, _ = _train(zspecs, state, ds, steps, 1e-2, seed=seed)
                ms, _ = evaluate(zspecs, state, acc, jax.random.PRNGKey(9),
                                 n_samples=10 if quick else 100)
                me, _ = evaluate(zspecs, state, acc, jax.random.PRNGKey(9),
                                 mode="continuous")
                accs_sampled.append(ms)
                accs_expected.append(me)
            rows.append({
                "bench": "table2_compression",
                "d": d, "compression": c,
                "sampled_acc": float(np.mean(accs_sampled)),
                "sampled_std": float(np.std(accs_sampled)),
                "expected_acc": float(np.mean(accs_expected)),
            })
    return rows


# ---------------------------------------------------------------------------
# Table 1 — communication savings (analytic, exact)
# ---------------------------------------------------------------------------

def comm_savings_table() -> List[Dict]:
    m = param_count(MNISTFC_DIMS)
    rows = []
    rows.append({
        "bench": "table1_comm", "method": "isik23_fedpm",
        "client_savings": 33.69, "server_savings": 1.05,
        "note": "paper-reported (*bit-rate 0.95 arithmetic coding)",
    })
    for comp in (8, 32):
        n = int(np.ceil(m / comp))
        rows.append({
            "bench": "table1_comm",
            "method": f"zampling m/n={comp}",
            "client_savings": 32.0 * m / n,  # n bits vs 32m bits
            "server_savings": float(m) / n,  # 32n vs 32m
            "note": f"m={m}, n={n} (MNISTFC)",
        })
    return rows


# ---------------------------------------------------------------------------
# §3.2 / Fig 4 — Federated Zampling, m/n in {1, 8, 32}
# ---------------------------------------------------------------------------

def run_federated(quick: bool = True) -> List[Dict]:
    ds = _dataset()
    acc = _acc_fn(ds)
    comps = [1, 8, 32]
    K = 10
    E = 40 if quick else 100
    rounds = 30 if quick else 100
    dims = SMALL_DIMS if quick else MNISTFC_DIMS
    rows = []
    for comp in comps:
        zspecs, state = _setup(dims, comp, d=10, seed=1)
        clients = iid_client_split(ds, K, seed=0)
        stream = client_batch_stream(clients, 64, E, seed=0)
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.5)

        @jax.jit
        def round_fn(state, batch, key):
            return federated_round(zspecs, state, mlp_loss, batch, key, cfg)

        key = jax.random.PRNGKey(0)
        curve = []
        for r in range(rounds):
            xs, ys = next(stream)
            key, sub = jax.random.split(key)
            state, met = round_fn(
                state, {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}, sub
            )
            if (r + 1) % max(rounds // 5, 1) == 0:
                ms, _ = evaluate(zspecs, state, acc, jax.random.PRNGKey(5),
                                 n_samples=10)
                curve.append(round(ms, 4))
        ms, mstd = evaluate(zspecs, state, acc, jax.random.PRNGKey(5),
                            n_samples=10 if quick else 100)
        wire = {
            r_["strategy"]: r_["uplink_bytes_per_client"]
            for r_ in wire_table(zspecs, K)
        }
        rows.append({
            "bench": "fig4_federated", "compression": comp,
            "final_sampled_acc": ms, "sampled_std": mstd,
            "curve": curve,
            "client_savings": 32.0 * zspecs.compression,
            "uplink_bytes_per_client": wire,
        })
    return rows


# ---------------------------------------------------------------------------
# Wire formats — measured bytes/round per transport + bit-exactness
# ---------------------------------------------------------------------------

def run_wire_formats(quick: bool = True) -> List[Dict]:
    """One federated round per registered transport on the same key:
    asserts the aggregated scores are BIT-IDENTICAL across strategies
    (exact equality — the transports differ only in wire format) and
    reports the exact per-round byte accounting for each."""
    ds = _dataset()
    K, E = 4, 2 if quick else 10
    comps = [8] if quick else [1, 8, 32]
    rows = []
    for comp in comps:
        zspecs, state = _setup(SMALL_DIMS, comp, d=10, seed=0)
        clients = iid_client_split(ds, K, seed=0)
        xs, ys = next(client_batch_stream(clients, 64, E, seed=0))
        batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
        key = jax.random.PRNGKey(0)
        scores = {}
        for row in wire_table(zspecs, K):
            cfg = FederatedConfig(num_clients=K, local_steps=E,
                                  local_lr=0.5, aggregate=row["strategy"])
            t0 = time.time()
            new_state, met = jax.jit(
                lambda s, b, k, cfg=cfg: federated_round(
                    zspecs, s, mlp_loss, b, k, cfg)
            )(state, batch, key)
            jax.block_until_ready(new_state)
            scores[row["strategy"]] = new_state["scores"]
            # f32 metric vs exact host accounting: equal to f32 rounding
            assert np.isclose(
                float(met["uplink_bytes_per_client"]),
                float(row["uplink_bytes_per_client"]), rtol=1e-6,
            )
            rows.append({**row, "bench": "wire_formats",
                         "compression": comp, "loss": float(met["loss"]),
                         "round_s": time.time() - t0})
        base = scores["mean_f32"]
        for name, sc in scores.items():
            for p in base:
                np.testing.assert_array_equal(
                    np.asarray(base[p]), np.asarray(sc[p]),
                    err_msg=f"{name} not bit-identical to mean_f32 at {p}",
                )
    return rows


# ---------------------------------------------------------------------------
# Downlink codecs — the accuracy / downlink-bits trade-off knob
# ---------------------------------------------------------------------------

def run_downlink_tradeoff(quick: bool = True) -> List[Dict]:
    """The paper's headline as a tunable protocol knob: the same
    federated run per registered downlink codec (f32 oracle, u16, u8,
    and the packed sub-byte packed4/packed2), reporting final sampled
    accuracy against metered downlink bytes; then the same run per
    RATE SCHEDULE (cosine anneal, frontier controller) — the adaptive
    rows spend fewer cumulative downlink bytes for the same final
    loss neighborhood as their fixed-width codec.  The f32 row is the
    bit-exact baseline; quantized rows trade the broadcast reduction
    for the codec's rounding noise in the round dynamics (the draws
    themselves stay exactly unbiased at the decoded probability — see
    comm.downlink)."""
    from ..comm.downlink import codec_names
    from ..core import encode_state
    from ..train import federated_fit

    ds = _dataset()
    acc = _acc_fn(ds)
    K, E = 4, 10 if quick else 40
    rounds = 10 if quick else 50
    rows = []

    def one_run(name, schedule="constant"):
        zspecs, state = _setup(SMALL_DIMS, 8, d=10, seed=1)
        extra = {}
        if schedule != "constant":
            extra = {"downlink_schedule": schedule, "schedule_b_min": 2}
            if schedule == "cosine":
                extra["schedule_rounds"] = rounds
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.5,
                              aggregate="psum_u32", downlink=name,
                              **extra)
        state = encode_state(zspecs, cfg, state)
        clients = iid_client_split(ds, K, seed=0)
        stream = client_batch_stream(clients, 64, E, seed=0)
        xs, ys = zip(*(next(stream) for _ in range(rounds)))
        batches = {"x": jnp.asarray(np.stack(xs)),
                   "y": jnp.asarray(np.stack(ys))}
        state, mets = jax.jit(
            lambda s, b, k, cfg=cfg, zs=zspecs: federated_fit(
                zs, s, mlp_loss, b, k, cfg)
        )(state, batches, jax.random.PRNGKey(0))
        ms, mstd = evaluate(zspecs, state, acc, jax.random.PRNGKey(5),
                            n_samples=10, carried=name)
        per_round = np.asarray(mets["downlink_bytes_per_client"],
                               np.float64)
        rep = round_wire_report(zspecs, cfg.aggregate, K, downlink=name)
        return {
            "bench": "downlink_tradeoff", "codec": name,
            "schedule": schedule, "K": K, "rounds": rounds,
            "final_sampled_acc": ms, "sampled_std": mstd,
            "final_loss": float(np.asarray(mets["loss"])[-1]),
            # realized (metered) bytes: the scheduled rows charge only
            # the scheduled width per round, lane padding included
            "downlink_bytes_per_client": float(per_round[-1]),
            "downlink_bytes_cumulative": float(per_round.sum()),
            "downlink_vs_f32": rep["downlink_vs_f32"],
        }

    for name in codec_names(include_aliases=False):
        rows.append(one_run(name))
    for schedule in ("cosine", "frontier"):
        for name in ("u8", "packed4"):
            rows.append(one_run(name, schedule))
    return rows


# ---------------------------------------------------------------------------
# Heterogeneity — accuracy vs Dirichlet beta per downlink codec
# ---------------------------------------------------------------------------

def run_heterogeneity(quick: bool = True) -> List[Dict]:
    """Accuracy under statistical heterogeneity: the same federated
    run across Dirichlet concentrations beta (smaller = more skewed
    label split) x registered downlink codecs, with the realistic
    cohort machinery — a Dirichlet population of unequal clients,
    ``ClientPopulation`` sampling a cohort per round, sample-count
    weights, and the streaming accumulator
    (``FederatedConfig.stream_chunk``) doing the aggregation, so the
    table exercises the exact path a memory-bounded server runs.
    Each row: (beta, codec) -> final sampled accuracy + downlink
    bytes.  The f32 codec rows are the oracle; the quantized rows show
    how much the broadcast can shrink before non-IID drift compounds
    with codec rounding."""
    from ..comm.downlink import codec_names
    from ..core import encode_state
    from ..data import cohort_batch_stream, dirichlet_client_split
    from ..fault import ClientPopulation
    from ..train import federated_fit

    ds = _dataset()
    acc = _acc_fn(ds)
    N, K, E = (8, 4, 10) if quick else (50, 10, 40)
    rounds = 10 if quick else 50
    betas = [0.1, 1.0] if quick else [0.05, 0.1, 0.5, 1.0, 10.0]
    rows = []
    for beta in betas:
        clients, hist = dirichlet_client_split(ds, N, beta=beta, seed=0)
        sizes = hist.sum(axis=1)
        pop = ClientPopulation(N, sample_counts=tuple(int(s) for s in sizes),
                               seed=0)
        for name in codec_names(include_aliases=False):
            zspecs, state = _setup(SMALL_DIMS, 8, d=10, seed=1)
            cfg = FederatedConfig(num_clients=K, local_steps=E,
                                  local_lr=0.5, aggregate="psum_u32",
                                  downlink=name, stream_chunk=max(K // 2, 1))
            state = encode_state(zspecs, cfg, state)
            stream = cohort_batch_stream(clients, pop, K, 64, E, seed=0)
            rows_r = [next(stream) for _ in range(rounds)]
            batches = {"x": jnp.asarray(np.stack([r[2] for r in rows_r])),
                       "y": jnp.asarray(np.stack([r[3] for r in rows_r]))}
            state, mets = jax.jit(
                lambda s, b, k, cfg=cfg, zs=zspecs, rr=rows_r: federated_fit(
                    zs, s, mlp_loss, b, k, cfg,
                    client_ids=jnp.asarray(np.stack([r[0] for r in rr])),
                    weights=jnp.asarray(np.stack([r[1] for r in rr])))
            )(state, batches, jax.random.PRNGKey(0))
            ms, mstd = evaluate(zspecs, state, acc, jax.random.PRNGKey(5),
                                n_samples=10, carried=name)
            rep = round_wire_report(zspecs, cfg.aggregate, K, downlink=name)
            rows.append({
                "bench": "heterogeneity", "beta": beta, "codec": name,
                "N": N, "K": K, "rounds": rounds,
                "final_sampled_acc": ms, "sampled_std": mstd,
                "final_loss": float(np.asarray(mets["loss"])[-1]),
                "downlink_bytes_per_client": rep["downlink_bytes_per_client"],
                "downlink_vs_f32": rep["downlink_vs_f32"],
            })
    return rows


# ---------------------------------------------------------------------------
# §3.3 / Table 4 — sensitivity: sampled vs regular training
# ---------------------------------------------------------------------------

def run_sensitivity(quick: bool = True) -> List[Dict]:
    ds = _dataset()
    acc = _acc_fn(ds)
    steps = 800 if quick else 4000
    taus = [0.01, 0.2, 0.5]
    n_pert = 5 if quick else 10
    rows = []
    for mode, label in (("sample", "sampled"), ("continuous", "regular")):
        zspecs, state = _setup(SMALL_DIMS, 2.0, 5, seed=0)
        state, _ = _train(zspecs, state, ds, steps, 1e-2, mode=mode)
        base_params = sample_weights(zspecs, state, jax.random.PRNGKey(3),
                                     mode="continuous")
        base = float(acc(base_params))
        for tau in taus:
            sens, devs, accs = [], [], []
            for i in range(n_pert):
                key = jax.random.PRNGKey(100 + i)
                pert_scores, eps_norms = {}, 0.0
                for path, s in state["scores"].items():
                    p2, eps = perturb_nontrivial(
                        s, jax.random.fold_in(key, hash(path) % 2**31), tau
                    )
                    pert_scores[path] = p2
                    eps_norms += float(jnp.sum(eps**2))
                eps_norm = np.sqrt(eps_norms)
                pstate = {"scores": pert_scores, "dense": state["dense"]}
                params = sample_weights(zspecs, pstate, jax.random.PRNGKey(4),
                                        mode="continuous")
                a = float(acc(params))
                accs.append(a)
                sens.append(abs(base - a) / max(base, 1e-9))
                devs.append(abs(base - a) / max(eps_norm, 1e-9))
            rows.append({
                "bench": "table4_sensitivity", "training": label, "tau": tau,
                "base_acc": base,
                "avg_acc": float(np.mean(accs)),
                "avg_sensitivity": float(np.mean(sens)),
                "avg_deviation": float(np.mean(devs)),
            })
    return rows


# ---------------------------------------------------------------------------
# App. A / Fig 5 — integrality gap vs initialisation
# ---------------------------------------------------------------------------

def run_integrality(quick: bool = True) -> List[Dict]:
    ds = _dataset()
    acc = _acc_fn(ds)
    steps = 800 if quick else 3000
    betas = [(0.1, 0.1), (1.0, 1.0)] if quick else [
        (0.05, 0.05), (0.1, 0.1), (0.5, 0.5), (1.0, 1.0), (2.0, 2.0)
    ]
    rows = []
    for beta in betas:
        # ContinuousModel: train w = Q p directly, NO sampling (App. A)
        zspecs, state = _setup(SMALL_DIMS, 2.0, 5, seed=0, beta=beta)
        state, _ = _train(zspecs, state, ds, steps, 1e-2, mode="continuous")
        exp_acc, _ = evaluate(zspecs, state, acc, jax.random.PRNGKey(5),
                              mode="continuous")
        samp_acc, samp_std = evaluate(zspecs, state, acc,
                                      jax.random.PRNGKey(5),
                                      n_samples=10 if quick else 100)
        disc_acc, _ = evaluate(zspecs, state, acc, jax.random.PRNGKey(5),
                               mode="discretize")
        rows.append({
            "bench": "fig5_integrality", "beta": beta,
            "expected_acc": exp_acc, "sampled_acc": samp_acc,
            "sampled_std": samp_std, "discretized_acc": disc_acc,
            "integrality_gap": exp_acc - samp_acc,
        })
    return rows


# ---------------------------------------------------------------------------
# App. B.1 / Fig 6 — comparison with Zhou et al. (d=1, n=m supermask)
# ---------------------------------------------------------------------------

def run_zhou_comparison(quick: bool = True) -> List[Dict]:
    ds = _dataset()
    acc = _acc_fn(ds)
    steps = 800 if quick else 4000
    dims = SMALL_DIMS if quick else MNISTFC_DIMS
    configs = [("zhou_d1_nm", 1.0, 1)] + [
        (f"zampling_d{d}", 1.0, d) for d in ([4, 16] if quick else
                                             [2, 4, 16, 256])
    ]
    rows = []
    for label, comp, d in configs:
        zspecs, state = _setup(dims, comp, d, seed=0)
        state, _ = _train(zspecs, state, ds, steps, 1e-2)
        ms, mstd = evaluate(zspecs, state, acc, jax.random.PRNGKey(5),
                            n_samples=10 if quick else 100)
        # best sampled mask (paper reports best of 100)
        best = max(
            float(acc(sample_weights(zspecs, state,
                                     jax.random.fold_in(
                                         jax.random.PRNGKey(6), i))))
            for i in range(10 if quick else 100)
        )
        rows.append({
            "bench": "fig6_zhou", "method": label, "d": d,
            "mean_sampled_acc": ms, "std": mstd, "best_mask_acc": best,
        })
    return rows
