#!/usr/bin/env bash
# Fast CI gate: tier-1 test subset + the reconstruction perf baseline.
#
#   bash scripts/ci.sh
#
# 1. runs the fast tier-1 tests (pytest.ini deselects @slow by default;
#    run `python -m pytest -m "" -q` for the full suite);
# 2. runs the kernel + batched-federated reconstruction benchmarks and
#    merges the rows into BENCH_reconstruct.json at the repo root, so
#    every PR leaves a perf trajectory the next one can diff against.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 (fast subset) =="
python -m pytest -x -q

echo "== reconstruction benchmarks -> BENCH_reconstruct.json =="
python -m benchmarks.run --only kernel,fedround

echo "== perf baseline =="
python - <<'EOF'
import json
rows = json.load(open("BENCH_reconstruct.json"))
for r in rows:
    if r.get("bench") == "federated_round_reconstruct":
        print(f"  K={r['K']:>3}: vmap={r['vmap_us']/1e3:8.1f}ms "
              f"batched={r['batched_us']/1e3:8.1f}ms "
              f"speedup={r['speedup']:.2f}x")
EOF
