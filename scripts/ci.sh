#!/usr/bin/env bash
# Fast CI gate: tier-1 test subset + the reconstruction/wire perf baselines.
#
#   bash scripts/ci.sh
#
# 1. runs the fast tier-1 tests (pytest.ini deselects @slow by default;
#    run `python -m pytest -m "" -q` for the full suite);
# 2. fails if the COMMITTED BENCH_reconstruct.json is stale — missing
#    the wire rows (all three transport strategies with byte
#    accounting) that the wire benchmark now emits — BEFORE
#    regenerating anything, so a PR that runs benchmarks locally but
#    never commits the refreshed baseline is caught;
# 3. runs the kernel + batched-federated reconstruction benchmarks AND
#    the wire-format transport benchmark, merging the rows into
#    BENCH_reconstruct.json at the repo root, so every PR leaves a perf
#    trajectory the next one can diff against.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 (fast subset) =="
python -m pytest -x -q

echo "== wire + fused staleness gate (committed BENCH_reconstruct.json) =="
python - <<'EOF'
import json
import sys

rows = json.load(open("BENCH_reconstruct.json"))
REQUIRED_STRATEGIES = {"mean_f32", "psum_u32", "allgather_packed"}
REQUIRED_KEYS = {"us", "uplink_bytes_per_client", "uplink_vs_f32", "K", "n"}
wire = [r for r in rows if r.get("bench") == "wire_aggregate"]
seen = {r.get("strategy") for r in wire}
missing = REQUIRED_STRATEGIES - seen
bad = [r for r in wire if not REQUIRED_KEYS <= set(r)]
if missing or bad:
    sys.exit(f"BENCH_reconstruct.json is stale: missing wire strategies "
             f"{sorted(missing)}; rows missing keys: {bad}. "
             f"Run `python -m benchmarks.run --only wire` and commit.")
print(f"  ok: {len(wire)} wire rows, strategies {sorted(seen)}")

# fused mask-lifecycle rows (same gate pattern as the wire rows): a PR
# that touches the fused kernels but never refreshes the baseline fails
# BEFORE any regeneration below.
FUSED_KEYS = {"fwd_fused_us", "fwd_composed_us", "fwd_speedup",
              "pack_fused_us", "pack_composed_us", "K", "n"}
fused = [r for r in rows if r.get("bench") == "fused_mask_lifecycle"]
ks = {r.get("K") for r in fused}
bad = [r for r in fused if not FUSED_KEYS <= set(r)]
if not {10, 32} <= ks or bad:
    sys.exit(f"BENCH_reconstruct.json is stale: fused rows present for "
             f"K={sorted(ks)} (need 10 and 32); rows missing keys: {bad}. "
             f"Run `python -m benchmarks.run --only fused` and commit.")
print(f"  ok: {len(fused)} fused rows, K={sorted(ks)}")

# transpose-plan backward rows: the plan path must be present for
# K in {10, 32} and must not regress below the scatter oracle
# (bwd_speedup >= 1.0).  NOTE the kernel_qz_reconstruct row keyed
# {"impl": "pallas_interpret"} is interpreter timing, NOT kernel perf
# — it is regression_comparable: false and excluded from every gate.
BWD_KEYS = {"scatter_bwd_us", "plan_bwd_us", "bwd_speedup", "fwd_us", "K"}
bwd = [r for r in rows if r.get("bench") == "bwd_transpose_plan"]
ks = {r.get("K") for r in bwd}
bad = [r for r in bwd if not BWD_KEYS <= set(r)]
slow = [r for r in bwd if r.get("bwd_speedup", 0) < 1.0]
if not {10, 32} <= ks or bad or slow:
    sys.exit(f"BENCH_reconstruct.json is stale or regressed: plan-bwd "
             f"rows for K={sorted(ks)} (need 10 and 32); missing keys: "
             f"{bad}; bwd_speedup < 1.0 (plan slower than scatter): "
             f"{slow}. Run `python -m benchmarks.run --only bwd` and "
             f"commit.")
print(f"  ok: {len(bwd)} plan-bwd rows, K={sorted(ks)}, min speedup "
      f"{min(r['bwd_speedup'] for r in bwd):.2f}x")

# batch-map threshold sweep rows (ROADMAP crossover re-measure): both
# forced strategies must be present so the tuned constant stays
# verifiable.
thr = [r for r in rows if r.get("bench") == "batch_map_threshold"]
strat = {r.get("strategy") for r in thr}
if not {"fused", "lax_map"} <= strat:
    sys.exit(f"BENCH_reconstruct.json is stale: batch_map_threshold "
             f"strategies {sorted(strat)} (need fused and lax_map). "
             f"Run `python -m benchmarks.run --only threshold` and commit.")
print(f"  ok: {len(thr)} threshold rows, strategies {sorted(strat)}")

# downlink codec rows: every registered codec must be present with the
# metered byte accounting, u8's mask-only downlink bytes must be
# <= 1/4 of the f32 broadcast, and the packed sub-byte codecs must
# deliver their lane-packed savings (packed4 <= 1/8 of f32 + one
# uint32 lane of tail padding per tensor) at <= 1.1x of u8's round
# wall-clock — the codec subsystem's headline savings must not
# silently regress.
DOWN_KEYS = {"us", "downlink_bytes_per_client", "downlink_vs_f32", "K", "n"}
down = [r for r in rows if r.get("bench") == "downlink_codec"]
codecs = {r.get("codec") for r in down}
bad = [r for r in down if not DOWN_KEYS <= set(r)]
if not {"f32", "u16", "u8", "packed4", "packed2"} <= codecs or bad:
    sys.exit(f"BENCH_reconstruct.json is stale: downlink codecs "
             f"{sorted(codecs)} (need f32, u16, u8, packed4, packed2); "
             f"rows missing keys: {bad}. Run `python -m benchmarks.run "
             f"--only downlink` and commit.")
by_key = {(r["codec"], r["K"]): r for r in down}
unpaired = [r for r in down if r["codec"] == "u8"
            and ("f32", r["K"]) not in by_key]
if unpaired:
    sys.exit(f"BENCH_reconstruct.json is stale: u8 downlink rows with no "
             f"f32 row at the same K: {unpaired}. Run `python -m "
             f"benchmarks.run --only downlink` and commit.")
fat = [r for r in down
       if r["codec"] == "u8"
       and r["downlink_bytes_per_client"]
       > by_key[("f32", r["K"])]["downlink_bytes_per_client"] / 4]
if fat:
    sys.exit(f"u8 downlink bytes exceed 1/4 of f32: {fat}")
# one uint32 lane of tail padding per tensor is the only allowed slack
# over the exact 1/8; n is the total coordinate count, so bound the
# tensor count by n (the slack term is tiny either way)
LANE_SLACK = 4 * 64
fat4 = [r for r in down
        if r["codec"] == "packed4"
        and r["downlink_bytes_per_client"]
        > by_key[("f32", r["K"])]["downlink_bytes_per_client"] / 8
        + LANE_SLACK]
if fat4:
    sys.exit(f"packed4 downlink bytes exceed 1/8 of f32 + lane slack: "
             f"{fat4}")
slow4 = [r for r in down
         if r["codec"] == "packed4"
         and ("u8", r["K"]) in by_key
         and r["us"] > 1.1 * by_key[("u8", r["K"])]["us"]]
if slow4:
    sys.exit(f"packed4 round wall-clock exceeds 1.1x of u8 (the in-block "
             f"lane unpack is no longer free): {slow4}")
print(f"  ok: {len(down)} downlink rows, codecs {sorted(codecs)}, "
      f"u8 <= 1/4 f32, packed4 <= 1/8 f32 at <= 1.1x u8 wall-clock")

# downlink schedule rows: the adaptive rate controller must be measured
# (constant on u8 plus cosine/frontier rows) with cumulative realized
# bytes, and the frontier run must undercut constant u8's cumulative
# downlink — the trade-off knob the schedule exists to turn.
SCHED_KEYS = {"us", "downlink_bytes_per_client", "downlink_bytes_cumulative",
              "rounds", "K", "n"}
sched = [r for r in rows if r.get("bench") == "downlink_schedule"]
strat = {r.get("strategy") for r in sched}
bad = [r for r in sched if not SCHED_KEYS <= set(r)]
if not {"constant_u8", "cosine_packed4", "frontier_u8"} <= strat or bad:
    sys.exit(f"BENCH_reconstruct.json is stale: downlink schedule rows "
             f"{sorted(strat)} (need constant_u8, cosine_packed4, "
             f"frontier_u8); rows missing keys: {bad}. Run `python -m "
             f"benchmarks.run --only downlink` and commit.")
by_strat = {r["strategy"]: r for r in sched}
if (by_strat["frontier_u8"]["downlink_bytes_cumulative"]
        >= by_strat["constant_u8"]["downlink_bytes_cumulative"]):
    sys.exit(f"frontier schedule no longer undercuts constant u8 "
             f"cumulative downlink: {by_strat['frontier_u8']} vs "
             f"{by_strat['constant_u8']}")
print(f"  ok: {len(sched)} schedule rows {sorted(strat)}, frontier "
      f"{by_strat['frontier_u8']['downlink_bytes_cumulative']:.0f}B < "
      f"constant u8 "
      f"{by_strat['constant_u8']['downlink_bytes_cumulative']:.0f}B")

# fault-round rows: the partial-participation engine must be measured
# at dropout {0, 0.2, 0.5} for K in {10, 32}, and the zero-fault
# configuration (weights all 1, empty FaultPlan) must cost <= 1.05x of
# the plain PR-5 round — the fault machinery is free when nothing
# fails, or the gate says otherwise.
FAULT_KEYS = {"us", "plain_us", "fault_overhead", "dropout", "K", "n"}
fau = [r for r in rows if r.get("bench") == "fault_round"]
ks = {r.get("K") for r in fau}
drops = {r.get("dropout") for r in fau}
bad = [r for r in fau if not FAULT_KEYS <= set(r)]
slow = [r for r in fau if r.get("dropout") == 0.0
        and r.get("fault_overhead", 99) > 1.05]
if not {10, 32} <= ks or not {0.0, 0.2, 0.5} <= drops or bad or slow:
    sys.exit(f"BENCH_reconstruct.json is stale or regressed: fault rows "
             f"for K={sorted(ks)} (need 10 and 32), dropout="
             f"{sorted(drops)} (need 0, 0.2, 0.5); rows missing keys: "
             f"{bad}; zero-fault overhead > 1.05x of the plain round: "
             f"{slow}. Run `python -m benchmarks.run --only faults` and "
             f"commit.")
print(f"  ok: {len(fau)} fault rows, K={sorted(ks)}, zero-fault overhead "
      f"{max(r['fault_overhead'] for r in fau if r['dropout'] == 0.0):.3f}x")

# streaming-round rows: the chunk-scan accumulator must be measured at
# K {10, 32, 128, 256} with slab-vs-stream timings and the analytic
# memory model; the small-K overhead must stay <= 1.05x of the slab
# round, and the streaming peak must be FLAT as K grows at a fixed
# chunk while the slab grows linearly — the unbounded-K claim, gated.
STREAM_KEYS = {"us", "slab_us", "stream_overhead", "chunk",
               "peak_upload_bytes", "slab_upload_bytes", "K", "n"}
strm = [r for r in rows if r.get("bench") == "streaming_round"]
ks = {r.get("K") for r in strm}
bad = [r for r in strm if not STREAM_KEYS <= set(r)]
slow = [r for r in strm if r.get("K") == 10
        and r.get("stream_overhead", 99) > 1.05]
if not {10, 32, 128, 256} <= ks or bad or slow:
    sys.exit(f"BENCH_reconstruct.json is stale or regressed: streaming "
             f"rows for K={sorted(ks)} (need 10, 32, 128, 256); rows "
             f"missing keys: {bad}; small-K streaming overhead > 1.05x "
             f"of the slab round: {slow}. Run `python -m benchmarks.run "
             f"--only streaming` and commit.")
by_chunk = {}
for r in strm:
    by_chunk.setdefault(r["chunk"], []).append(r)
for chunk, group in by_chunk.items():
    peaks = {r["peak_upload_bytes"] for r in group}
    if len(peaks) != 1:
        sys.exit(f"streaming peak memory varies with K at chunk={chunk}: "
                 f"{sorted(peaks)} — the accumulator is no longer "
                 f"K-independent")
grow = [r for r in strm if r["K"] >= 128
        and r["slab_upload_bytes"] <= r["peak_upload_bytes"]]
if grow:
    sys.exit(f"slab upload memory no longer dwarfs the streaming peak at "
             f"large K: {grow}")
big = [r for r in strm if r["K"] == 256 and r["chunk"] == 8]
if not big or big[0]["slab_upload_bytes"] / big[0]["peak_upload_bytes"] < 5:
    sys.exit(f"K=256 slab-vs-streaming-peak ratio collapsed: {big}")
print(f"  ok: {len(strm)} streaming rows, K={sorted(ks)}, K=10 overhead "
      f"{max(r['stream_overhead'] for r in strm if r['K'] == 10):.3f}x, "
      f"peak flat per chunk")

# serve_decode rows: dense / load / streaming must be measured at both
# model widths (K = d_model in {256, 512}); the streaming mode's
# resident zampled bytes must stay strictly below the load mode's (the
# tentpole claim — never materialize a weight), with at least a 4x
# reduction at the largest width.  Rows with regression_comparable:
# false (the interpret-mode Pallas step) are excluded from every
# comparison, same convention as kernel_qz_reconstruct.
SERVE_KEYS = {"us", "tok_s", "resident_zampled_bytes", "dense_bytes",
              "strategy", "impl", "K"}
srv = [r for r in rows if r.get("bench") == "serve_decode"
       and r.get("regression_comparable", True)]
ks = {r.get("K") for r in srv}
strat = {r.get("strategy") for r in srv}
bad = [r for r in srv if not SERVE_KEYS <= set(r)]
if not {256, 512} <= ks or not {"dense", "load", "streaming"} <= strat \
        or bad:
    sys.exit(f"BENCH_reconstruct.json is stale: serve_decode rows for "
             f"K={sorted(ks)} (need 256 and 512), strategies "
             f"{sorted(strat)} (need dense, load, streaming); rows "
             f"missing keys: {bad}. Run `python -m benchmarks.run "
             f"--only serve` and commit.")
by_mode = {}
for r in srv:
    by_mode[(r["strategy"], r["K"])] = r
for k in sorted(ks):
    stream = by_mode[("streaming", k)]
    load = by_mode[("load", k)]
    if stream["resident_zampled_bytes"] >= load["resident_zampled_bytes"]:
        sys.exit(f"streaming resident zampled bytes "
                 f"{stream['resident_zampled_bytes']} not below load's "
                 f"{load['resident_zampled_bytes']} at K={k} — the "
                 f"decode-time reconstruction no longer saves memory")
kmax = max(ks)
ratio = (by_mode[("load", kmax)]["resident_zampled_bytes"]
         / by_mode[("streaming", kmax)]["resident_zampled_bytes"])
if ratio < 4:
    sys.exit(f"streaming resident reduction collapsed to {ratio:.2f}x at "
             f"K={kmax} (need >= 4x)")
if not all(r.get("bit_exact_vs_load") for r in srv
           if r["strategy"] == "streaming"):
    sys.exit("serve_decode streaming rows lost the bit_exact_vs_load "
             "attestation — the pre-timing equality assert was skipped")
print(f"  ok: {len(srv)} serve rows, K={sorted(ks)}, streaming resident "
      f"{ratio:.1f}x below load at K={kmax}")

# serve_delta rows: the XOR round update must be metered for every
# codec and must undercut the full broadcast by at least 8x on the
# converged-round scenario, or the hot-swap path has regressed into
# re-broadcasting.
DELTA_KEYS = {"words_total", "words_changed", "delta_bytes", "full_bytes",
              "delta_vs_full", "strategy"}
dlt = [r for r in rows if r.get("bench") == "serve_delta"]
codecs = {r.get("strategy") for r in dlt}
bad = [r for r in dlt if not DELTA_KEYS <= set(r)]
fat = [r for r in dlt if r.get("delta_bytes", 1 << 60)
       >= r.get("full_bytes", 0) or r.get("delta_vs_full", 1) > 0.125]
if not {"f32", "u16", "u8"} <= codecs or bad or fat:
    sys.exit(f"BENCH_reconstruct.json is stale or regressed: serve_delta "
             f"codecs {sorted(codecs)} (need f32, u16, u8); rows missing "
             f"keys: {bad}; delta >= full broadcast or > 1/8 of it: "
             f"{fat}. Run `python -m benchmarks.run --only serve` and "
             f"commit.")
print(f"  ok: {len(dlt)} delta rows, codecs {sorted(codecs)}, worst "
      f"delta/full {max(r['delta_vs_full'] for r in dlt):.4f}")

# serve_batch rows: continuous batching x the hot-block cache.  Every
# (batch, mode) cell must be measured with the pre-timing bitwise
# attestation; the cached mode must hold >= 2x pure streaming tok/s at
# the largest batch (or the tile pool no longer pays for itself), and
# the converged-round retention row must keep >= 90% of the cache
# (or drawn-bit invalidation has regressed to word granularity).
# ``strategy="scheduler"`` rows carry regression_comparable: false
# (host control-plane pacing) and are excluded, same convention as the
# interpret-mode Pallas rows.
BATCH_KEYS = {"tok_s", "us", "strategy", "K", "cache_budget_bytes",
              "resident_bytes", "cache_bytes"}
sb = [r for r in rows if r.get("bench") == "serve_batch"
      and r.get("regression_comparable", True)
      and r.get("strategy") != "retention"]
ks = {r["K"] for r in sb}
strat = {r["strategy"] for r in sb}
bad = [r for r in sb if not BATCH_KEYS <= set(r)]
if not {1, 4, 16} <= ks or not {"load", "streaming", "cached"} <= strat \
        or bad:
    sys.exit(f"BENCH_reconstruct.json is stale: serve_batch rows for "
             f"B={sorted(ks)} (need 1, 4, 16), strategies "
             f"{sorted(strat)} (need load, streaming, cached); rows "
             f"missing keys: {bad}. Run `python -m benchmarks.run "
             f"--only serve_batch` and commit.")
if not all(r.get("bit_exact_across_modes") for r in sb):
    sys.exit("serve_batch rows lost the bit_exact_across_modes "
             "attestation — the pre-timing equality assert was skipped")
kmax = max(ks)
by = {(r["strategy"], r["K"]): r for r in sb}
speedup = by[("cached", kmax)]["tok_s"] / by[("streaming", kmax)]["tok_s"]
if speedup < 2:
    sys.exit(f"hot-block cache speedup collapsed to {speedup:.2f}x over "
             f"streaming at B={kmax} (need >= 2x)")
over = [r for r in sb if r["strategy"] == "cached"
        and r["cache_bytes"] > r["cache_budget_bytes"]]
if over:
    sys.exit(f"cached rows exceed their own pool budget: {over}")
ret = [r for r in rows if r.get("bench") == "serve_batch"
       and r.get("strategy") == "retention"]
if len(ret) != 1 or ret[0].get("retained_fraction", 0) < 0.9:
    sys.exit(f"serve_batch retention row missing or < 0.9: {ret}. Run "
             f"`python -m benchmarks.run --only serve_batch` and commit.")
print(f"  ok: {len(sb)} serve_batch rows, B={sorted(ks)}, cached "
      f"{speedup:.2f}x streaming at B={kmax}, delta retention "
      f"{ret[0]['retained_fraction']:.3f}")
EOF

echo "== reconstruction + fused + bwd + wire + downlink + fault + streaming + serve benchmarks -> BENCH_reconstruct.json =="
python -m benchmarks.run --only kernel,fedround,fused,bwd,threshold,wire,downlink,faults,streaming,serve,serve_batch

echo "== perf baseline =="
python - <<'EOF'
import json

rows = json.load(open("BENCH_reconstruct.json"))
for r in rows:
    if r.get("bench") == "federated_round_reconstruct":
        print(f"  K={r['K']:>3}: vmap={r['vmap_us']/1e3:8.1f}ms "
              f"batched={r['batched_us']/1e3:8.1f}ms "
              f"speedup={r['speedup']:.2f}x")
    elif r.get("bench") == "wire_aggregate":
        print(f"  wire {r['strategy']:>17} K={r['K']:>3}: "
              f"{r['us']/1e3:8.1f}ms  up={r['uplink_bytes_per_client']:>10}B "
              f"({r['uplink_vs_f32']:.4f}x f32)")
    elif r.get("bench") == "fused_mask_lifecycle":
        print(f"  fused K={r['K']:>3}: fwd {r['fwd_fused_us']/1e3:8.1f}ms "
              f"vs composed {r['fwd_composed_us']/1e3:8.1f}ms "
              f"({r['fwd_speedup']:.3f}x); lifecycle "
              f"{r['lifecycle_speedup']:.3f}x")
    elif r.get("bench") == "bwd_transpose_plan":
        print(f"  bwd  K={r['K']:>3}: plan {r['plan_bwd_us']/1e3:8.1f}ms "
              f"vs scatter {r['scatter_bwd_us']/1e3:8.1f}ms "
              f"({r['bwd_speedup']:.2f}x); bwd:fwd "
              f"{r['bwd_fwd_ratio_plan']:.2f}")
    elif r.get("bench") == "downlink_codec":
        print(f"  down {r['codec']:>17} K={r['K']:>3}: "
              f"{r['us']/1e3:8.1f}ms  "
              f"down={r['downlink_bytes_per_client']:>10}B "
              f"({r['downlink_vs_f32']:.4f}x f32)")
    elif r.get("bench") == "downlink_schedule":
        print(f"  dsched {r['strategy']:>16}: {r['us']/1e3:8.1f}ms/round  "
              f"cum={r['downlink_bytes_cumulative']:>8.0f}B over "
              f"{r['rounds']} rounds")
    elif r.get("bench") == "fault_round":
        print(f"  fault dropout={r['dropout']:<4} K={r['K']:>3}: "
              f"{r['us']/1e3:8.1f}ms vs plain {r['plain_us']/1e3:8.1f}ms "
              f"({r['fault_overhead']:.3f}x)")
    elif r.get("bench") == "streaming_round":
        print(f"  strm chunk={r['chunk']:<3} K={r['K']:>3}: "
              f"{r['us']/1e3:8.1f}ms vs slab {r['slab_us']/1e3:8.1f}ms "
              f"({r['stream_overhead']:.3f}x); peak "
              f"{r['peak_upload_bytes']/1024:.0f}KiB vs slab "
              f"{r['slab_upload_bytes']/1024:.0f}KiB "
              f"({r['slab_vs_peak']:.1f}x)")
    elif r.get("bench") == "serve_decode":
        tag = "" if r.get("regression_comparable", True) else "  [interpret]"
        print(f"  serve {r['strategy']:>9} d={r['K']:>3}: "
              f"{r['tok_s']:6.2f} tok/s  resident "
              f"{r['resident_zampled_bytes']/1024:8.0f}KiB{tag}")
    elif r.get("bench") == "serve_delta":
        print(f"  sdelta {r['strategy']:>8}: changed "
              f"{r['words_changed']:>6}/{r['words_total']} words  "
              f"delta {r['delta_bytes']:>8}B vs full {r['full_bytes']:>8}B "
              f"({r['delta_vs_full']:.4f}x)")
    elif r.get("bench") == "serve_batch":
        if r.get("strategy") == "retention":
            print(f"  sbatch retention: "
                  f"{r['retained_tiles']}/{r['total_tiles']} tiles "
                  f"({r['retained_fraction']:.3f}) after 1%-moved round")
        else:
            tag = "" if r.get("regression_comparable", True) \
                else "  [scheduler]"
            print(f"  sbatch {r['strategy']:>9} B={r['K']:>2}: "
                  f"{r['tok_s']:6.2f} tok/s{tag}")
EOF
