"""Dump the largest result arrays + collectives of one dry-run combo.

  python scripts/analyze_hlo.py <arch> <shape> [mode]
"""
import sys

sys.path.insert(0, "src")
import os  # noqa: E402

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re  # noqa: E402
from collections import Counter  # noqa: E402

import jax  # noqa: E402

from repro.launch.dryrun import (  # noqa: E402
    DTYPE_BYTES,
    build_decode,
    build_prefill,
    build_train_baseline,
    build_train_zampling,
)
from repro.configs.registry import get_arch, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

arch, shape_name = sys.argv[1], sys.argv[2]
mode = sys.argv[3] if len(sys.argv) > 3 else "zampling"
cfg = get_arch(arch)
shape = get_shape(shape_name)
mesh = make_production_mesh()
if shape.kind == "train":
    b = build_train_zampling if mode == "zampling" else build_train_baseline
    jf, args, _ = b(cfg, shape, mesh)
elif shape.kind == "prefill":
    jf, args, _ = build_prefill(cfg, shape, mesh)
else:
    wo = 4096 if (shape_name == "long_500k" and cfg.window is None
                  and cfg.family in ("dense", "moe")) else None
    jf, args, _ = build_decode(cfg, shape, mesh, window_override=wo)
with jax.set_mesh(mesh):
    c = jf.lower(*args).compile()
print("temp GB:", c.memory_analysis().temp_size_in_bytes / 1e9)
txt = c.as_text()
sizes = Counter()
for m in re.finditer(r"= (\w+)\[([0-9,]+)\]\{[^}]*\} (\w[\w-]*)\(", txt):
    dt, dims, op = m.group(1), m.group(2), m.group(3)
    if dt not in DTYPE_BYTES:
        continue
    n = 1
    for d in dims.split(","):
        n *= int(d)
    nb = n * DTYPE_BYTES[dt]
    if nb > 200e6:
        sizes[(dt, dims, op, nb)] += 1
for (dt, dims, op, nb), cnt in sorted(sizes.items(),
                                      key=lambda kv: -kv[0][3])[:20]:
    print(f"{nb/1e9:7.2f}GB x{cnt:3d}  {dt}[{dims}] {op}")
